"""Routing-integrated serving scheduler — the paper's technique, deployed.

A serving cluster (TPU slices + edge ingress points + interconnect) is
modeled as the paper's computing network: slice i becomes node i with
``mu_u`` = achievable FLOP/s, interconnect hops become links with ``mu_uv``
bytes/s, and the per-slice backlog of already-scheduled work is exactly the
queue vector Q the formulation charges waiting time against.

Since the time-aware state split the scheduler holds the two parts
explicitly: one immutable :class:`~repro.core.state.Topology` for the life
of the deployment and a :class:`~repro.core.state.QueueState` that evolves
— ``commit`` grows it, :meth:`RoutedScheduler.advance` drains it while the
clock runs.  Solvers see the zero-copy composed view ``topo.view(state)``;
nothing rebuilds arrays.

Two drain models are threaded through (``drain="fluid" | "exact"``):

  * ``"fluid"`` (default, bit-identical to the pre-ledger behaviour):
    every resource drains independently at full rate, q <- max(q - mu dt,
    0).  Fast, optimistic — it serves link bytes whose producing compute
    hasn't finished and node FLOPs out of priority order.
  * ``"exact"``: a :class:`~repro.core.completions.CommittedWork` ledger
    records every committed plan's work items (priority + precedence), and
    time passing drains *exactly those jobs* through the preempt-resume
    event loop the simulator uses.  The solver-visible ``QueueState`` is
    materialized from the ledger's residual work, so every bound is charged
    against committed work, not rate-capacity fluid.

``track_commits=True`` additionally keeps a never-drained commit *log* (a
second ledger) regardless of drain mode — the full-horizon ground-truth
replay record the fidelity benchmark compares both models against.

Every batch of inference requests is turned into InferenceJobs via the
architecture cost profiles (configs/<arch>.cost_profile) and placed through
the unified solver entry point (``solvers.solve`` — greedy by default, any
registered method by name): each request gets (a) the nodes computing each
layer range — i.e. a layer-wise model split when transfers are cheap
relative to queueing, or a single fast node when they are not — and (b) a
priority.  The solver's :class:`~repro.core.plan.Plan` is stored whole;
:class:`Placement` objects are per-job *views* over it, so the full plan
(including its queue state and provenance) can be serialized, shipped, or
re-planned without reassembling anything.

Straggler mitigation falls out of the formulation: a slow or overloaded
slice has a long queue (or degraded mu_u after ``report_slowdown``), so its
waiting term grows and new jobs route around it — tests/test_serving.py
asserts this end-to-end.  ``replan_last`` re-places the most recent batch
against the updated cluster health (incremental re-plan: the pre-batch
queue state is restored, the stored jobs re-solved, and the new plan
committed in place of the old one).
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.core import completions as C, jobs as J, network as N, solvers
from repro.core.state import QueueState, Topology, effective_topology
from repro.core.plan import Plan
from repro.configs import registry


def check_slowdown_factor(factor: float) -> float:
    """Validate a straggler slowdown factor (the "factor=2 means half
    speed" convention): must be finite and > 0, since the effective
    topology divides by it — factor <= 0 would produce negative or
    infinite capacities."""
    factor = float(factor)
    if not np.isfinite(factor) or factor <= 0:
        raise ValueError(
            f"slowdown factor must be finite and > 0 (factor=2 means half "
            f"speed, factor=1 restores full health), got {factor}")
    return factor


@dataclasses.dataclass(frozen=True)
class Placement:
    """View over one job of a stored :class:`Plan`."""

    plan: Plan
    job: int                    # row in the plan
    job_name: str
    num_layers: int

    @property
    def priority(self) -> int:
        return int(self.plan.priority[self.job])

    @property
    def assign(self) -> np.ndarray:
        """[L] node per (real) layer."""
        return self.plan.job_assign(self.job, self.num_layers)

    @property
    def bound_s(self) -> float:
        """Completion-time upper bound."""
        return float(self.plan.bounds[self.job])

    @property
    def nodes_used(self) -> list[int]:
        seen = []
        for n in self.assign:
            if not seen or seen[-1] != n:
                seen.append(int(n))
        return seen


@dataclasses.dataclass
class Request:
    arch: str
    src: int
    dst: int
    seq_len: int = 2048
    batch: int = 1
    name: str = ""


def requests_to_jobs(requests: list[Request]) -> list[J.InferenceJob]:
    """Cost-profile each request into an :class:`InferenceJob`."""
    infer_jobs = []
    for i, r in enumerate(requests):
        comp, data = registry.cost_profile(r.arch, seq_len=r.seq_len,
                                           batch=r.batch)
        infer_jobs.append(J.InferenceJob(
            r.name or f"req{i}", r.src, r.dst,
            comp.astype(np.float32), data.astype(np.float32)))
    return infer_jobs


class RoutedScheduler:
    drain_queues: bool = True  # OnlineScheduler's no-drain baseline flips this

    def __init__(self, net: N.ComputeNetwork | Topology, *,
                 method: str = "greedy", drain: str = "fluid",
                 track_commits: bool = False, sim_engine: str = "indexed",
                 **solver_opts):
        if isinstance(net, Topology):
            self.topology = net
            self.state = net.empty_state()
        else:
            self.topology = net.topology
            self.state = net.state
        if drain not in ("fluid", "exact"):
            raise ValueError(
                f"drain must be 'fluid' or 'exact', got {drain!r}")
        if sim_engine not in ("indexed", "ref"):
            raise ValueError(
                f"sim_engine must be 'indexed' or 'ref', got {sim_engine!r}")
        self.method = method
        # Exact-drain event engine: "indexed" (persistent O(log)-per-event
        # index threaded through drains/commits/replans) or "ref" (the seed
        # linear-scan loop — benchmarks/drain_bench.py races the two).
        self.sim_engine = sim_engine
        self.solver_opts = solver_opts
        # Authoritative clock, host-side float64: ``state.clock`` (f32, so it
        # loses sub-second ticks past ~2^24 s if accumulated) is only ever
        # *stamped* from this, never summed.
        self._now = float(np.asarray(self.state.clock))
        self._slowdown = np.ones((self.topology.num_nodes,), np.float32)
        # Availability masks (the fault layer's state): failed nodes lose
        # compute *and* every incident link; links can also fail alone.
        self._avail_node = np.ones((self.topology.num_nodes,), bool)
        self._link_up = np.ones((self.topology.num_nodes,) * 2, bool)
        self.drain_mode = drain
        # Live registry of committed InferenceJobs (exact mode): the fault
        # policies reconstruct residual jobs from it when a resource fails.
        self.inflight_jobs: dict[str, J.InferenceJob] = {}
        # Exact mode: the committed-work ledger is the source of truth for
        # backlogs; the solver-visible QueueState is materialized from it.
        self.ledger: C.CommittedWork | None = (
            C.CommittedWork.empty(self.topology.num_nodes, clock=self._now)
            if drain == "exact" else None)
        # Optional never-drained commit log (ground-truth replay record).
        self.commit_log: C.CommittedWork | None = (
            C.CommittedWork.empty(self.topology.num_nodes, clock=self._now)
            if track_commits else None)
        # (batch, jobs, pre-batch state, health + clock + ledgers at snapshot)
        self._last: tuple[J.JobBatch, list[J.InferenceJob], QueueState,
                          Topology, float, C.CommittedWork | None,
                          C.CommittedWork | None] | None = None
        self.last_plan: Plan | None = None
        # Why the most recent replan_last() call did / did not commit:
        # None (never called) | "replanned" | "no_batch" | "no_improvement".
        self.last_replan_reason: str | None = None
        # Solver wall-time telemetry: per-call and cumulative.  The
        # streaming pipeline's "measured" latency model reads these to put
        # real solve latency on the simulated clock.
        self.last_solve_s: float = 0.0
        self.total_solve_s: float = 0.0

    # -- compatibility views ------------------------------------------------
    @property
    def net(self) -> N.ComputeNetwork:
        """Current composed view (base topology + live queue state)."""
        return self.topology.view(self.state)

    @property
    def base_net(self) -> N.ComputeNetwork:
        """Healthy-capacity view with empty queues."""
        return self.topology.view()

    # -- cluster health / time ---------------------------------------------
    def _check_slowdown(self, node: int, factor: float) -> float:
        """Validate a slowdown event's arguments (raises ``ValueError``)."""
        factor = check_slowdown_factor(factor)
        if not (0 <= int(node) < self.topology.num_nodes):
            raise ValueError(f"node {node} out of range "
                             f"[0, {self.topology.num_nodes})")
        return factor

    def report_slowdown(self, node: int, factor: float) -> None:
        """Straggling slice: effective mu_u /= factor from now on.

        ``factor`` follows the "factor=2 means half speed" convention: the
        node's effective capacity becomes mu_u / factor (it serves *and
        drains* slower), ``factor=1`` restores full health.  Raises
        ``ValueError`` for factor <= 0 or non-finite factors, and for a
        node outside the topology.  When a commit log is kept the event is
        recorded there too, so ``replay_piecewise`` can reconstruct the
        true segment-by-segment health history.
        """
        self._slowdown[node] = self._check_slowdown(node, factor)
        if self.commit_log is not None:
            self.commit_log = self.commit_log.record_slowdown(
                self._now, node, self._slowdown[node])

    def report_recovery(self, node: int) -> None:
        """Straggler cleared: restore the node's effective rate to full
        health — the inverse of :meth:`report_slowdown`, i.e. factor back
        to 1.0.  Raises ``ValueError`` for a node outside the topology.
        Recorded in the commit log's health history (when kept), so
        ``replay_piecewise`` sees the recovery window instead of treating
        the last reported slowdown as permanent.
        """
        if not (0 <= int(node) < self.topology.num_nodes):
            raise ValueError(f"node {node} out of range "
                             f"[0, {self.topology.num_nodes})")
        self.report_slowdown(int(node), 1.0)

    def _check_node(self, node: int) -> int:
        node = int(node)
        if not (0 <= node < self.topology.num_nodes):
            raise ValueError(f"node {node} out of range "
                             f"[0, {self.topology.num_nodes})")
        return node

    @property
    def degraded(self) -> bool:
        """Any node or link currently failed?"""
        return not (self._avail_node.all() and self._link_up.all())

    def set_node_availability(self, node: int, up: bool) -> None:
        """Infrastructure event: the node (and implicitly every incident
        link — a dead node cannot relay) fails or recovers from now on.

        Recovery restores *full* health: the node's slowdown factor resets
        to 1.0 (rejoining capacity is assumed re-provisioned, and a
        recovery record of the stale factor would misstate the replay).
        Recorded in the commit log's health history as ``factor=inf``
        (down) / ``1.0`` (up), the encoding ``replay_piecewise`` consumes.
        """
        node = self._check_node(node)
        self._avail_node[node] = bool(up)
        if up:
            self._slowdown[node] = 1.0
        if self.commit_log is not None:
            self.commit_log = self.commit_log.record_health(
                self._now, node, 1.0 if up else np.inf)

    def set_link_availability(self, u: int, v: int, up: bool) -> None:
        """Infrastructure event on one *directed* link (u -> v); callers
        modeling a bidirectional cut flip both directions.  Raises for a
        link that does not exist in the base topology (mu_uv == 0) — its
        failure could never matter, so reporting one is a caller bug.
        """
        u, v = self._check_node(u), self._check_node(v)
        if float(np.asarray(self.topology.mu_link)[u, v]) <= 0:
            raise ValueError(
                f"link ({u}, {v}) does not exist in the topology "
                f"(mu_link[{u}, {v}] == 0); availability events apply "
                f"to real links only")
        self._link_up[u, v] = bool(up)
        if self.commit_log is not None:
            self.commit_log = self.commit_log.record_health(
                self._now, ("link", u, v), 1.0 if up else np.inf)

    def _down_keys(self) -> tuple:
        """Engine-facing resource keys currently failed (() when healthy)."""
        if not self.degraded:
            return ()
        return C.down_keys(self.topology, self._avail_node, self._link_up)

    def _drain_state(self, dt: float) -> None:
        """Advance backlogs ``dt`` seconds at effective (health-aware) rates
        under the configured drain model.  Does not move the clock."""
        if self.drain_mode == "exact":
            self.ledger = C.drain_exact(self._effective_topology(),
                                        self.ledger, dt,
                                        engine=self.sim_engine,
                                        down=self._down_keys())
            self._sync_ledger_queues()
        else:
            self.state = self.state.advance(self._effective_topology(), dt)

    def _sync_ledger_queues(self) -> None:
        """Materialize the ledger's residual work into the QueueState."""
        import jax.numpy as jnp
        qn, ql = self.ledger.queue_arrays()
        self.state = self.state.with_queues(jnp.asarray(qn), jnp.asarray(ql))

    def advance(self, dt: float) -> None:
        """Let ``dt`` seconds pass: the backlog drains at effective rates
        (fluid or exact per ``drain_mode``) and the clock moves forward."""
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        self._drain_state(dt)
        self._now += float(dt)
        self._stamp_clock()

    def _stamp_clock(self) -> None:
        import jax.numpy as jnp
        self.state = dataclasses.replace(self.state,
                                         clock=jnp.float32(self._now))

    @property
    def clock(self) -> float:
        return self._now

    def drain(self) -> None:
        """All scheduled work finished: reset queues (clock preserved).

        In exact mode the ledger's live jobs are dropped without recording
        completions; ``commit_log`` (a pure record of what was committed)
        is left untouched.
        """
        import jax.numpy as jnp
        self.state = self.state.with_queues(
            jnp.zeros_like(self.state.q_node),
            jnp.zeros_like(self.state.q_link))
        if self.ledger is not None:
            self.ledger = self.ledger.cleared()
        self._last = None
        self.last_plan = None

    def stats(self) -> dict:
        """Solve-time/closure-build telemetry of the most recent placement.

        ``closure_builds`` counts host-level min-plus closure builds during
        the solve — the reference round loop reports exactly J (one build
        per round, so a regression that reintroduces per-call rebuilds
        shows up here first) while the fused solver reports 0 (its closure
        work happens inside the device program; the honest per-solve
        accounting is ``fused``/``dispatches``/``rounds_per_dispatch``).
        """
        if self.last_plan is None:
            return {}
        m = self.last_plan.meta
        return {k: m[k] for k in ("method", "solve_s", "solve_share_s",
                                  "closure_builds", "n_routings", "fused",
                                  "dispatches", "rounds_per_dispatch",
                                  "windows_per_dispatch", "jit_compiled")
                if k in m}

    def _effective_topology(self) -> Topology:
        if not self.degraded:
            # bit-identical to the pre-fault expression (and rates)
            return effective_topology(self.topology, self._slowdown)
        return effective_topology(self.topology, self._slowdown,
                                  self._avail_node, self._link_up)

    # -- placement ----------------------------------------------------------
    def _placements(self, plan: Plan,
                    infer_jobs: list[J.InferenceJob]) -> list[Placement]:
        # Walk priority slots directly, so the list is born sorted.
        out = [Placement(plan=plan, job=int(j),
                         job_name=infer_jobs[j].name,
                         num_layers=infer_jobs[j].num_layers)
               for j in plan.order]
        assert [p.priority for p in out] == list(range(len(out)))
        return out

    # Solvers that can fill plan.paths during the solve, reusing each
    # round's closures (greedy.greedy_route(extract_paths=True)).  For any
    # other method _ledger_commit falls back to a full replay_solution.
    _PATH_SOLVERS = ("greedy", "greedy_ref", "lazy")

    def _want_paths(self, method: str) -> bool:
        return ((self.ledger is not None or self.commit_log is not None)
                and method in self._PATH_SOLVERS)

    def _commit_plan(self, topo: Topology, batch: J.JobBatch, plan: Plan,
                     pre_state: QueueState,
                     names: list[str] | None) -> Plan:
        """Commit one solved plan: queue state, ledger/commit-log, telemetry.

        Shared by the per-batch path (:meth:`commit_presolved`) and the
        cross-arrival fused path (:meth:`schedule_windows`), which solves
        W windows in one dispatch and then commits them through here one
        at a time (``pre_state`` = the queue state that window was solved
        against).
        """
        if plan.net is None:  # e.g. the exact solver reports no queue state
            plan = dataclasses.replace(
                plan, net=plan.commit(topo.view(pre_state), batch))
        if self.ledger is None:
            # Committed backlogs come from the plan; the clock is ours to
            # keep.  (In exact mode the ledger sync below is authoritative,
            # so the fluid commit would be a dead store.)
            self.state = self.state.with_queues(plan.net.q_node,
                                                plan.net.q_link)
        if self.ledger is not None or self.commit_log is not None:
            plan = self._ledger_commit(topo, batch, plan, pre_state, names)
        self.last_plan = plan
        # Fused multi-window plans carry the shared dispatch's wall in
        # solve_s and their per-window share in solve_share_s; accumulate
        # the share so total_solve_s sums to real wall, not W * wall.
        self.last_solve_s = float(plan.meta.get(
            "solve_share_s", plan.meta.get("solve_s", 0.0)))
        self.total_solve_s += self.last_solve_s
        return plan

    def _ledger_commit(self, topo: Topology, batch: J.JobBatch, plan: Plan,
                       pre_state: QueueState,
                       names: list[str] | None) -> Plan:
        """Record the committed plan's work items (exact ledger and/or the
        ground-truth commit log)."""
        from repro.core import schedule
        if plan.paths is None:
            # Paths against the solve-time queue state — exactly the hops
            # the plan's bounds charged (Alg. 1 / Alg. 2 semantics).
            _, paths, _ = schedule.replay_solution(
                topo.view(pre_state), batch, plan.assign, plan.order)
            plan = dataclasses.replace(plan, paths=paths)
        if self.ledger is not None:
            self.ledger = self.ledger.commit(batch, plan, names=names,
                                             at=self._now)
            if self.sim_engine == "indexed":
                # First commit births the persistent index; later commits
                # extend it in place inside CommittedWork.commit.
                self.ledger = C.warm_engine(topo, self.ledger)
            # Ledger is the source of truth in exact mode: rounding of the
            # committed queues must match what later drains will report.
            self._sync_ledger_queues()
        if self.commit_log is not None:
            self.commit_log = self.commit_log.commit(batch, plan,
                                                     names=names,
                                                     at=self._now)
        return plan

    def presolve(self, infer_jobs: list[J.InferenceJob],
                 *, pad_to: int | None = None,
                 method: str | None = None) -> tuple[J.JobBatch, Plan]:
        """Pure candidate solve against the current state: no commit, no
        queue/ledger/telemetry mutation.  The admission controller scores
        the returned plan with ``completions.predict_completions`` before
        deciding whether to commit it (:meth:`commit_presolved`)."""
        batch = J.batch_jobs(infer_jobs, pad_to=pad_to)
        method = self.method if method is None else method
        opts = self.solver_opts
        if self._want_paths(method):
            opts = {"extract_paths": True, **opts}
        plan = solvers.solve(self._effective_topology(), batch,
                             method=method, state=self.state, **opts)
        return batch, plan

    def commit_presolved(self, infer_jobs: list[J.InferenceJob],
                         batch: J.JobBatch, plan: Plan) -> list[Placement]:
        """Commit a plan solved by :meth:`presolve` against the *unchanged*
        current state — the second half of :meth:`schedule_jobs`."""
        pre_state = self.state
        pre_ledger, pre_log = self.ledger, self.commit_log
        plan = self._commit_plan(self._effective_topology(), batch, plan,
                                 pre_state, [j.name for j in infer_jobs])
        # Record only after the commit succeeds, so a raising solver can't
        # poison replan_last() with a batch that was never scheduled.
        self._last = (batch, infer_jobs, pre_state,
                      self._effective_topology(), self._now,
                      pre_ledger, pre_log)
        if self.ledger is not None:
            # Fault policies rebuild residual jobs from this registry;
            # prune lazily once dead entries dominate (mirrors the
            # engine cache's bloat rule — amortized O(1) per job).
            for j in infer_jobs:
                self.inflight_jobs[j.name] = j
            if (len(self.inflight_jobs) >= 2048
                    and len(self.inflight_jobs) > 2 * len(self.ledger.jobs)):
                live = {j.name for j in self.ledger.jobs}
                self.inflight_jobs = {n: j for n, j in
                                      self.inflight_jobs.items() if n in live}
        return self._placements(plan, infer_jobs)

    def schedule_jobs(self, infer_jobs: list[J.InferenceJob],
                      *, pad_to: int | None = None,
                      method: str | None = None) -> list[Placement]:
        """Place pre-built :class:`InferenceJob`s (the online loop's path).

        ``method`` overrides the configured solver for this batch only —
        the fault layer's migrate policy re-places residual jobs with the
        ``"migrate"`` solver while regular traffic keeps the default.
        """
        batch, plan = self.presolve(infer_jobs, pad_to=pad_to, method=method)
        return self.commit_presolved(infer_jobs, batch, plan)

    def schedule(self, requests: list[Request]) -> list[Placement]:
        return self.schedule_jobs(requests_to_jobs(requests))

    def schedule_windows(self, windows: list[list[J.InferenceJob]],
                         *, pad_to: int | None = None,
                         method: str | None = None) -> list[list[Placement]]:
        """Place several queued arrival windows in **one** fused dispatch.

        Windows are solved in order, each against the previous window's
        committed queues (``solvers.solve_fused``), then committed one at
        a time so the ledger/commit-log records match W sequential
        :meth:`schedule_jobs` calls.  Only the fused greedy has a
        multi-window device program; any other method falls back to
        sequential scheduling (same results, W dispatches).
        """
        method = self.method if method is None else method
        if not windows:
            self._window_states = []
            return []
        if method != "greedy" or len(windows) == 1:
            out = []
            self._window_states = []
            for jobs in windows:
                out.append(self.schedule_jobs(jobs, pad_to=pad_to,
                                              method=method))
                self._window_states.append(self.state)
            return out
        topo = self._effective_topology()
        batches = [J.batch_jobs(jobs, pad_to=pad_to) for jobs in windows]
        opts = self.solver_opts
        if self._want_paths(method):
            opts = {"extract_paths": True, **opts}
        plans = solvers.solve_fused(topo, batches, state=self.state,
                                    pad_to=pad_to, **opts)
        out = []
        # Per-window post-commit queue snapshots: after _commit_plan,
        # self.state is authoritative (ledger-synced in exact mode, plan
        # queues in fluid), so telemetry reading these matches what W
        # sequential schedule_jobs calls would have recorded.
        self._window_states = []
        for jobs, batch, plan in zip(windows, batches, plans):
            pre_state = self.state
            plan = self._commit_plan(topo, batch, plan, pre_state,
                                     [j.name for j in jobs])
            self._last = (batch, jobs, pre_state, topo, self._now,
                          self.ledger, self.commit_log)
            if self.ledger is not None:
                for j in jobs:
                    self.inflight_jobs[j.name] = j
            out.append(self._placements(plan, jobs))
            self._window_states.append(self.state)
        return out

    def warmup(self, sample_jobs: list[J.InferenceJob],
               *, pad_to: int | None = None, max_jobs: int | None = None,
               window_counts: tuple[int, ...] = ()) -> dict:
        """Pre-compile the fused solve at this deployment's serving shapes.

        Runs throwaway solves (pure — no queue state, ledger, clock, or
        telemetry mutation) so that steady-state arrivals never pay a jit
        compile wall: one per power-of-two job-count bucket up to
        ``max_jobs`` (default: ``len(sample_jobs)``), plus one fused
        multi-window program per entry of ``window_counts``.  The
        streaming pipeline's "measured" latency model assumes warmed
        shapes; re-compiles that still slip through (an unseen model mix,
        a new window count) are flagged by ``meta["jit_compiled"]`` and
        excluded from its EMA.  Returns ``{"compiles": n, "wall_s": w,
        "warm_solve_s": s}`` — ``warm_solve_s`` times one *post-compile*
        solve at the largest warmed size, the seed the pipeline's
        "measured" latency EMA starts from (stream.py's cold-start fix:
        before the first real observation the model returned 0.0, so the
        first window's admission predictions were systematically
        optimistic).
        """
        if self.method != "greedy" or not sample_jobs:
            return {"compiles": 0, "wall_s": 0.0, "warm_solve_s": 0.0}
        t0 = time.perf_counter()
        topo = self._effective_topology()
        opts = dict(self.solver_opts)
        if self._want_paths(self.method):
            opts = {"extract_paths": True, **opts}
        top = max_jobs if max_jobs is not None else len(sample_jobs)
        sizes, s = [], 1
        while s < top:
            sizes.append(s)
            s *= 2
        sizes.append(s)
        cyc = list(itertools.islice(itertools.cycle(sample_jobs), sizes[-1]))
        compiles = 0
        for size in sizes:
            plan = solvers.solve(topo, J.batch_jobs(cyc[:size], pad_to=pad_to),
                                 method=self.method, state=self.state, **opts)
            compiles += int(plan.meta.get("jit_compiled", False))
        for w in window_counts:
            if w < 2:
                continue
            batches = [J.batch_jobs(cyc[: sizes[-1]], pad_to=pad_to)
                       for _ in range(w)]
            plans = solvers.solve_fused(topo, batches, state=self.state,
                                        pad_to=pad_to, **opts)
            compiles += int(plans[0].meta.get("jit_compiled", False))
        wall = time.perf_counter() - t0
        # One more solve at the largest (already-compiled) size: a clean
        # compile-excluded wall measurement for the latency-model seed.
        t1 = time.perf_counter()
        plan = solvers.solve(topo, J.batch_jobs(cyc, pad_to=pad_to),
                             method=self.method, state=self.state, **opts)
        warm = time.perf_counter() - t1
        if plan.meta.get("jit_compiled", False):   # unseen shape slipped in
            t1 = time.perf_counter()
            solvers.solve(topo, J.batch_jobs(cyc, pad_to=pad_to),
                          method=self.method, state=self.state, **opts)
            warm = time.perf_counter() - t1
        return {"compiles": compiles, "wall_s": wall + warm,
                "warm_solve_s": warm}

    def replan_last(self, *, min_improvement: float | None = None
                    ) -> list[Placement] | None:
        """Re-place the most recent batch against updated cluster health.

        Rolls the queue state back to just before that batch was committed,
        re-solves with the current slowdown factors, and commits the new
        plan — incremental re-planning after ``report_slowdown`` without the
        caller resubmitting requests.  Returns None if nothing to re-plan;
        :attr:`last_replan_reason` records why (``no_batch`` — nothing was
        scheduled, or ``no_improvement``) so monitor decisions are
        auditable.

        ``min_improvement`` (default None = always commit, the manual-call
        semantics) gates the commit on the re-solve actually helping: the
        old assignment is re-scored under *current* health and the
        rolled-back queues, and the new plan commits only if its worst
        bound beats that by the given relative margin (0.0 = any strict
        improvement).  On decline nothing is mutated — the auto-replan
        monitor uses this so hysteresis never pays for a no-op re-commit.
        """
        self.last_replan_reason = "no_batch"
        if self._last is None:
            return None
        import jax.numpy as jnp
        (batch, infer_jobs, pre_state, pre_topo, pre_now,
         pre_ledger, pre_log) = self._last
        # Pre-batch backlogs, drained over the time elapsed since they were
        # captured (work that was genuinely served must not resurrect) at the
        # *snapshot-time* health — the rates that actually applied until the
        # event that triggered this replan (exact for the canonical
        # report_slowdown-then-replan flow; piecewise health histories are
        # approximated by their first segment).  The clock never rolls back.
        # Everything is computed locally first: a declined replan (the
        # min_improvement gate) must leave the scheduler untouched.
        elapsed = self._now - pre_now
        ledger = None
        if self.drain_mode == "exact":
            ledger = pre_ledger
            if elapsed > 0 and self.drain_queues:
                # The snapshot's engine slot went stale the moment the live
                # chain drained past it, so this rollback drain rebuilds the
                # index lazily from the snapshot's immutable job records.
                ledger = C.drain_exact(pre_topo, ledger, elapsed,
                                       engine=self.sim_engine)
            qn, ql = ledger.queue_arrays()
            state = pre_state.with_queues(jnp.asarray(qn), jnp.asarray(ql))
        else:
            state = pre_state
            if elapsed > 0 and self.drain_queues:
                state = state.advance(pre_topo, elapsed)
        state = dataclasses.replace(state, clock=jnp.float32(self._now))
        # Candidate re-solve at current health against the rolled-back
        # queues (pure — nothing committed yet).
        topo = self._effective_topology()
        opts = self.solver_opts
        if self._want_paths(self.method):
            opts = {"extract_paths": True, **opts}
        plan = solvers.solve(topo, batch, method=self.method, state=state,
                             **opts)
        if min_improvement is not None:
            from repro.core import schedule
            old = self.last_plan
            new_cost = float(np.asarray(plan.bounds, np.float64).max())
            if old is None:
                improved = True
            else:
                old_bounds, _, _ = schedule.replay_solution(
                    topo.view(state), batch, old.assign, old.order)
                old_cost = float(old_bounds.max())
                improved = (new_cost < old_cost * (1.0 - min_improvement)
                            - schedule.time_eps(old_cost))
            if not improved:
                self.last_replan_reason = "no_improvement"
                return None
        # Committing: apply the rollback, then the new plan.
        self.ledger = ledger if self.drain_mode == "exact" else self.ledger
        self.state = state
        # The superseded batch never ran to completion: drop it from the
        # ground-truth record too (same approximation as the state rollback)
        # — but keep the full health history, which rollback cannot undo.
        if pre_log is not None and self.commit_log is not None:
            pre_log = dataclasses.replace(pre_log,
                                          health=self.commit_log.health)
        self.commit_log = pre_log
        plan = self._commit_plan(topo, batch, plan, self.state,
                                 [j.name for j in infer_jobs])
        self.last_replan_reason = "replanned"
        return self._placements(plan, infer_jobs)
