"""Routing-integrated serving scheduler — the paper's technique, deployed.

A serving cluster (TPU slices + edge ingress points + interconnect) is
modeled as the paper's computing network: slice i becomes node i with
``mu_u`` = achievable FLOP/s, interconnect hops become links with ``mu_uv``
bytes/s, and the per-slice backlog of already-scheduled work is exactly the
queue vector Q the formulation charges waiting time against.

Every batch of inference requests is turned into InferenceJobs via the
architecture cost profiles (configs/<arch>.cost_profile) and placed with
Algorithm 1 (greedy): each request gets (a) the nodes computing each layer
range — i.e. a layer-wise model split when transfers are cheap relative to
queueing, or a single fast node when they are not — and (b) a priority.

Straggler mitigation falls out of the formulation: a slow or overloaded
slice has a long queue (or degraded mu_u after ``report_slowdown``), so its
waiting term grows and new jobs route around it — tests/test_serving.py
asserts this end-to-end.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import greedy, jobs as J, network as N
from repro.configs import registry


@dataclasses.dataclass
class Placement:
    job_name: str
    priority: int
    assign: np.ndarray          # [L] node per layer
    bound_s: float              # completion-time upper bound

    @property
    def nodes_used(self) -> list[int]:
        seen = []
        for n in self.assign:
            if not seen or seen[-1] != n:
                seen.append(int(n))
        return seen


@dataclasses.dataclass
class Request:
    arch: str
    src: int
    dst: int
    seq_len: int = 2048
    batch: int = 1
    name: str = ""


class RoutedScheduler:
    def __init__(self, net: N.ComputeNetwork):
        self.base_net = net
        self.net = net
        self._slowdown = np.ones((net.num_nodes,), np.float32)

    # -- cluster health -----------------------------------------------------
    def report_slowdown(self, node: int, factor: float) -> None:
        """Straggling slice: effective mu_u /= factor from now on."""
        self._slowdown[node] = factor

    def drain(self) -> None:
        """All scheduled work finished: reset queues."""
        self.net = self.net.reset_queues()

    def _effective_net(self) -> N.ComputeNetwork:
        import jax.numpy as jnp
        mu = self.base_net.mu_node / jnp.asarray(self._slowdown)
        return dataclasses.replace(self.net, mu_node=mu)

    # -- placement ----------------------------------------------------------
    def schedule(self, requests: list[Request]) -> list[Placement]:
        infer_jobs = []
        for i, r in enumerate(requests):
            mod = registry.get(r.arch)
            if r.arch in registry.PAPER_MODELS:
                comp, data = mod.cost_profile(batch=r.batch)
            else:
                comp, data = mod.cost_profile(seq_len=r.seq_len, batch=r.batch)
            infer_jobs.append(J.InferenceJob(
                r.name or f"req{i}", r.src, r.dst,
                comp.astype(np.float32), data.astype(np.float32)))
        batch = J.batch_jobs(infer_jobs)
        sol = greedy.greedy_route(self._effective_net(), batch)
        self.net = dataclasses.replace(
            self.net, q_node=sol.net.q_node, q_link=sol.net.q_link)
        out = []
        for p, j in enumerate(sol.order):
            L = infer_jobs[j].num_layers
            out.append(Placement(
                job_name=infer_jobs[j].name, priority=p,
                assign=sol.assign[j][:L], bound_s=float(sol.bounds[j])))
        return sorted(out, key=lambda x: x.priority)
