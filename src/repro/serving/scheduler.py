"""Routing-integrated serving scheduler — the paper's technique, deployed.

A serving cluster (TPU slices + edge ingress points + interconnect) is
modeled as the paper's computing network: slice i becomes node i with
``mu_u`` = achievable FLOP/s, interconnect hops become links with ``mu_uv``
bytes/s, and the per-slice backlog of already-scheduled work is exactly the
queue vector Q the formulation charges waiting time against.

Every batch of inference requests is turned into InferenceJobs via the
architecture cost profiles (configs/<arch>.cost_profile) and placed through
the unified solver entry point (``solvers.solve`` — greedy by default, any
registered method by name): each request gets (a) the nodes computing each
layer range — i.e. a layer-wise model split when transfers are cheap
relative to queueing, or a single fast node when they are not — and (b) a
priority.  The solver's :class:`~repro.core.plan.Plan` is stored whole;
:class:`Placement` objects are per-job *views* over it, so the full plan
(including its queue state and provenance) can be serialized, shipped, or
re-planned without reassembling anything.

Straggler mitigation falls out of the formulation: a slow or overloaded
slice has a long queue (or degraded mu_u after ``report_slowdown``), so its
waiting term grows and new jobs route around it — tests/test_serving.py
asserts this end-to-end.  ``replan_last`` re-places the most recent batch
against the updated cluster health (incremental re-plan: the pre-batch
queue state is restored, the stored jobs re-solved, and the new plan
committed in place of the old one).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import jobs as J, network as N, solvers
from repro.core.plan import Plan
from repro.configs import registry


@dataclasses.dataclass(frozen=True)
class Placement:
    """View over one job of a stored :class:`Plan`."""

    plan: Plan
    job: int                    # row in the plan
    job_name: str
    num_layers: int

    @property
    def priority(self) -> int:
        return int(self.plan.priority[self.job])

    @property
    def assign(self) -> np.ndarray:
        """[L] node per (real) layer."""
        return self.plan.job_assign(self.job, self.num_layers)

    @property
    def bound_s(self) -> float:
        """Completion-time upper bound."""
        return float(self.plan.bounds[self.job])

    @property
    def nodes_used(self) -> list[int]:
        seen = []
        for n in self.assign:
            if not seen or seen[-1] != n:
                seen.append(int(n))
        return seen


@dataclasses.dataclass
class Request:
    arch: str
    src: int
    dst: int
    seq_len: int = 2048
    batch: int = 1
    name: str = ""


class RoutedScheduler:
    def __init__(self, net: N.ComputeNetwork, *, method: str = "greedy",
                 **solver_opts):
        self.base_net = net
        self.net = net
        self.method = method
        self.solver_opts = solver_opts
        self._slowdown = np.ones((net.num_nodes,), np.float32)
        self._last: tuple[J.JobBatch, list[J.InferenceJob],
                          N.ComputeNetwork] | None = None
        self.last_plan: Plan | None = None

    # -- cluster health -----------------------------------------------------
    def report_slowdown(self, node: int, factor: float) -> None:
        """Straggling slice: effective mu_u /= factor from now on."""
        self._slowdown[node] = factor

    def drain(self) -> None:
        """All scheduled work finished: reset queues."""
        self.net = self.net.reset_queues()
        self._last = None
        self.last_plan = None

    def stats(self) -> dict:
        """Solve-time/closure-build telemetry of the most recent placement.

        ``closure_builds`` counts host-level min-plus closure builds during
        the solve — with the round-level reuse pipeline a greedy solve over
        J jobs reports exactly J (one build per round), so a regression that
        reintroduces per-call rebuilds shows up here first.
        """
        if self.last_plan is None:
            return {}
        m = self.last_plan.meta
        return {k: m[k] for k in ("method", "solve_s", "closure_builds",
                                  "n_routings") if k in m}

    def _effective_net(self) -> N.ComputeNetwork:
        import jax.numpy as jnp
        mu = self.base_net.mu_node / jnp.asarray(self._slowdown)
        return dataclasses.replace(self.net, mu_node=mu)

    # -- placement ----------------------------------------------------------
    def _placements(self, plan: Plan,
                    infer_jobs: list[J.InferenceJob]) -> list[Placement]:
        # Walk priority slots directly, so the list is born sorted.
        out = [Placement(plan=plan, job=int(j),
                         job_name=infer_jobs[j].name,
                         num_layers=infer_jobs[j].num_layers)
               for j in plan.order]
        assert [p.priority for p in out] == list(range(len(out)))
        return out

    def _solve_and_commit(self, batch: J.JobBatch) -> Plan:
        plan = solvers.solve(self._effective_net(), batch,
                             method=self.method, **self.solver_opts)
        if plan.net is None:  # e.g. the exact solver reports no queue state
            plan = dataclasses.replace(
                plan, net=plan.commit(self._effective_net(), batch))
        self.net = dataclasses.replace(
            self.net, q_node=plan.net.q_node, q_link=plan.net.q_link)
        self.last_plan = plan
        return plan

    def schedule(self, requests: list[Request]) -> list[Placement]:
        infer_jobs = []
        for i, r in enumerate(requests):
            mod = registry.get(r.arch)
            if r.arch in registry.PAPER_MODELS:
                comp, data = mod.cost_profile(batch=r.batch)
            else:
                comp, data = mod.cost_profile(seq_len=r.seq_len, batch=r.batch)
            infer_jobs.append(J.InferenceJob(
                r.name or f"req{i}", r.src, r.dst,
                comp.astype(np.float32), data.astype(np.float32)))
        batch = J.batch_jobs(infer_jobs)
        pre_net = self.net
        plan = self._solve_and_commit(batch)
        # Record only after the solve succeeds, so a raising solver can't
        # poison replan_last() with a batch that was never scheduled.
        self._last = (batch, infer_jobs, pre_net)
        return self._placements(plan, infer_jobs)

    def replan_last(self) -> list[Placement] | None:
        """Re-place the most recent batch against updated cluster health.

        Rolls the queue state back to just before that batch was committed,
        re-solves with the current slowdown factors, and commits the new
        plan — incremental re-planning after ``report_slowdown`` without the
        caller resubmitting requests.  Returns None if nothing to re-plan.
        """
        if self._last is None:
            return None
        batch, infer_jobs, pre_net = self._last
        self.net = pre_net
        plan = self._solve_and_commit(batch)
        return self._placements(plan, infer_jobs)
