"""Streaming serving pipeline: ingestion queue, batching window, decoupled
solver/drain stages with backpressure (ROADMAP item 2).

The serial online loop (:func:`repro.serving.online.run_online`) handles one
arrival at a time: drain -> solve -> commit, a full solver invocation per
request.  At scale the loop is *solver-bound* — per-call dispatch and
bookkeeping dominate (``BENCH_drain.json``: ~24 ms/job at us-backbone:lm) —
so this module restructures serving as a simulated-time pipeline of three
decoupled stages:

  1. **Ingestion queue + batching window.**  Arrivals stream in one epoch at
     a time (:func:`repro.core.arrivals.stream_times` /
     ``Scenario.job_stream`` are the iterator views).  The first admitted
     request opens a *window*; the window closes after ``window_s`` (δ)
     simulated seconds or as soon as ``max_batch`` (B) requests have
     accumulated, whichever comes first, and the whole window is placed in
     **one** scheduler entry — one drain sync, one backlog accounting
     pass, one trace record.  ``solve_mode`` picks the solver shape
     inside it: one padded batched solve (``batch_jobs(pad_to=)`` keeps
     the layer width jit-stable — the accelerator-friendly operand), or
     ``"sequential"`` width-1 solves in window order (the serial loop's
     plans with the per-entry overhead still amortized — the faster shape
     when the solver runs on CPU, where a padded batch's extra per-round
     candidate evaluations cost more than the dispatch they save).  A
     partial window left open when the stream ends is flushed at the
     horizon.
  2. **Decoupled solver and drain stages.**  Closed windows queue for a
     single solver server; its wall-time is *modeled on the simulated
     clock* (``solver_latency`` — a constant, or ``"measured"``: an EMA of
     the real solve walls the scheduler reports via ``last_solve_s``), so
     solve latency itself delays commits and a slow solver visibly backs
     the system up.  With ``fuse_windows > 1`` (batched mode) the solver
     server drains up to that many queued windows per start in **one**
     cross-arrival fused dispatch (:meth:`OnlineScheduler.submit_windows`)
     — when the solver falls behind and windows pile up, each dispatch
     clears several of them at once instead of paying per-window dispatch
     overhead serially.  Solve walls that paid a jit compile
     (``meta["jit_compiled"]``) are excluded from the ``"measured"`` EMA
     and recorded separately (``StreamTrace.compile_walls``) — a single
     compile wall would otherwise poison the latency model for the rest
     of the run; :func:`run_stream`'s ``warmup=True`` pre-compiles the
     serving shapes so steady state never pays one.  The drain — the authoritative
     :class:`~repro.core.eventsim.EventEngine` clock in exact mode, the
     fluid model otherwise — advances independently underneath: the
     scheduler drains to each *commit* instant, not to each arrival, so
     committed work keeps being served while windows fill and solves run.
  3. **Backpressure.**  At most ``max_pending`` admitted-but-uncommitted
     requests are in flight.  When the solver falls behind, further
     arrivals are *deferred* (they wait in a FIFO spill queue and are
     admitted — in arrival order, so backpressure never reorders them — as
     commits free capacity, with the extra wait charged to their latency)
     or, with ``policy="shed"``, dropped and accounted.

Per-request latency decomposes as **wait + service**: wait is everything
before the plan lands (window residence + solver queue + modeled solve
latency), service is the solver's completion bound from the commit instant.
:class:`StreamTrace` extends the serial :class:`OnlineTrace` with that
decomposition, per-window records, shed/deferral accounting, and a
sustained-throughput summary — throughput as a first-class benchmark axis
(``benchmarks/stream_bench.py``).

Correctness gate: with δ=0, B=1 and zero modeled solver latency every
window is a single request committed at its own arrival instant, and the
pipeline reproduces the serial ``OnlineScheduler`` trace **bit-identically**
(``tests/test_stream.py`` and the ``pipeline_matches_serial`` benchmark
flag assert it).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
from typing import Iterable, Sequence

import numpy as np

from repro.core import arrivals as A, jobs as J
from repro.core.state import Topology
from .online import OnlineScheduler, OnlineTrace


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs of the streaming pipeline.

    ``window_s`` (δ) and ``max_batch`` (B) shape the batching window;
    ``solve_mode`` picks the solver shape inside each window's single
    scheduler entry (``"batched"``: one padded batched solve — the
    accelerator-friendly operand; ``"sequential"``: width-1 solves in
    window order — serial plans, amortized dispatch);
    ``solver_latency`` models the solver stage's wall-time on the simulated
    clock (seconds per solve, or ``"measured"`` for an EMA of the real
    solve walls); ``max_pending`` bounds the admitted-but-uncommitted
    buffer and ``policy`` picks what happens to arrivals beyond it
    (``"defer"`` queues them FIFO, ``"shed"`` drops them).
    ``fuse_windows`` lets one solver start drain up to that many queued
    windows in a single cross-arrival fused dispatch (batched mode only;
    the default 1 preserves the window-per-dispatch behaviour the δ=0/B=1
    serial-parity gate is defined over).
    """

    window_s: float = 0.0
    max_batch: int = 1
    solve_mode: str = "batched"
    solver_latency: float | str = 0.0
    max_pending: int | None = None
    policy: str = "defer"
    fuse_windows: int = 1

    def __post_init__(self):
        if self.window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {self.window_s}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.fuse_windows < 1:
            raise ValueError(
                f"fuse_windows must be >= 1, got {self.fuse_windows}")
        if self.policy not in ("defer", "shed"):
            raise ValueError(
                f"policy must be 'defer' or 'shed', got {self.policy!r}")
        if self.solve_mode not in ("batched", "sequential"):
            raise ValueError(f"solve_mode must be 'batched' or "
                             f"'sequential', got {self.solve_mode!r}")
        if self.max_pending is not None and self.max_pending < 1:
            raise ValueError(
                f"max_pending must be >= 1 (or None), got {self.max_pending}")
        if isinstance(self.solver_latency, str):
            if self.solver_latency != "measured":
                raise ValueError(
                    f"solver_latency must be seconds or 'measured', got "
                    f"{self.solver_latency!r}")
        elif not (float(self.solver_latency) >= 0):
            raise ValueError(
                f"solver_latency must be >= 0, got {self.solver_latency}")


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """Latency decomposition of one committed request."""

    name: str
    window: int          # index of the window that carried it
    arrival_s: float     # instant the request arrived at the pipeline
    admit_s: float       # instant it entered a window (> arrival if deferred)
    close_s: float       # instant its window closed (flush or B reached)
    commit_s: float      # instant its plan landed (clock of the solve)
    solve_s: float       # modeled solver latency charged to its window
    service_s: float     # solver's completion bound from the commit instant

    @property
    def wait_s(self) -> float:
        """Everything before service: window residence + solver queue +
        modeled solve latency."""
        return self.commit_s - self.arrival_s

    @property
    def queue_s(self) -> float:
        """Solver-queue share of the wait (window close -> solve start)."""
        return (self.commit_s - self.solve_s) - self.close_s

    @property
    def latency_s(self) -> float:
        return self.wait_s + self.service_s


@dataclasses.dataclass(frozen=True)
class WindowRecord:
    """One batching window's life cycle."""

    index: int
    open_s: float
    close_s: float
    commit_s: float
    size: int
    solve_model_s: float   # latency modeled on the simulated clock
    solve_wall_s: float    # wall-time the solve actually took


@dataclasses.dataclass
class StreamTrace(OnlineTrace):
    """:class:`OnlineTrace` + the streaming decomposition.

    ``records`` (inherited) holds one :class:`ArrivalRecord` per *window*
    commit — so every serial-trace metric (p99, backlog growth) reads the
    same way — while ``requests`` decomposes each request's latency into
    wait/solve/service and ``windows``/``shed``/``deferred`` account for
    the batching and backpressure machinery.
    """

    requests: list[RequestRecord] = dataclasses.field(default_factory=list)
    windows: list[WindowRecord] = dataclasses.field(default_factory=list)
    # ``shed`` is inherited from OnlineTrace: backpressure / fault / solver
    # sheds and the admission layer's rejections share one list and one
    # ``shed_by_reason`` accounting.
    deferred: int = 0
    # Solve walls that paid a jit compile (meta["jit_compiled"]): kept out
    # of the "measured" EMA and reported separately in summary().
    compile_walls: list[float] = dataclasses.field(default_factory=list)

    def _field(self, name: str) -> np.ndarray:
        return np.array([getattr(r, name) for r in self.requests],
                        np.float64)

    @property
    def waits(self) -> np.ndarray:
        return self._field("wait_s")

    @property
    def services(self) -> np.ndarray:
        return self._field("service_s")

    @property
    def solves(self) -> np.ndarray:
        return self._field("solve_s")

    def sustained_arr_s(self) -> float:
        """Committed requests per simulated second, first arrival to last
        commit — the throughput the pipeline actually *sustained* (a
        backed-up solver stretches the commit horizon and lowers it)."""
        if len(self.requests) < 2:
            return float("nan")
        span = (max(r.commit_s for r in self.requests)
                - min(r.arrival_s for r in self.requests))
        if span <= 0:
            return float("nan")
        return len(self.requests) / span

    def summary(self) -> dict:
        out = super().summary()
        out.update({
            "windows": len(self.windows),
            "mean_window": (len(self.requests) / len(self.windows)
                            if self.windows else float("nan")),
            "deferred": self.deferred,
            "shed": len(self.shed),
            "sustained_arr_s": self.sustained_arr_s(),
            "compile_solves": len(self.compile_walls),
            "compile_wall_s": float(sum(self.compile_walls)),
        })
        if self.requests:
            for key, arr in (("wait", self.waits), ("solve", self.solves),
                             ("service", self.services)):
                out[f"p50_{key}_s"] = float(np.percentile(arr, 50))
                out[f"p99_{key}_s"] = float(np.percentile(arr, 99))
        return out

    def to_dict(self) -> dict:
        return {
            **super().to_dict(),
            "requests": [dataclasses.asdict(r) | {
                "wait_s": r.wait_s, "latency_s": r.latency_s}
                for r in self.requests],
            "window_records": [dataclasses.asdict(w) for w in self.windows],
            "shed_records": list(self.shed),
        }


@dataclasses.dataclass
class _Admit:
    job: J.InferenceJob
    arrival_s: float
    admit_s: float


@dataclasses.dataclass
class _Window:
    index: int
    open_s: float
    jobs: list[_Admit]
    close_s: float = 0.0


# Event ordering at equal simulated instants: an infrastructure fault
# applies first (commits at the same instant already see the post-event
# topology), then a commit frees buffer capacity (and admits deferred
# work) before a window-deadline flush fires, and both precede any new
# arrival at the same instant — so deferred requests are always
# re-admitted ahead of later traffic and FIFO order is preserved.
_FAULT, _COMMIT, _FLUSH, _ARRIVAL = -1, 0, 1, 2


class StreamingPipeline:
    """Simulated-time event loop over arrival / flush / commit events.

    Wraps an :class:`OnlineScheduler` (or builds one from a
    :class:`Topology`): the scheduler stays the single authority for the
    clock, the drain and every plan commit — the pipeline only decides
    *when* windows of requests reach it, via the
    :meth:`OnlineScheduler.submit_window` hook.
    """

    def __init__(self, net: Topology | OnlineScheduler,
                 config: StreamConfig | None = None, **sched_opts):
        self.config = config or StreamConfig()
        if isinstance(net, OnlineScheduler):
            if sched_opts:
                raise ValueError("pass scheduler options only when the "
                                 "pipeline builds the scheduler itself")
            self.sched = net
        else:
            self.sched = OnlineScheduler(net, **sched_opts)
        # The pipeline owns one fresh run: its trace replaces the
        # scheduler's so both record into the same (stream-aware) object.
        self.sched.trace = StreamTrace()
        self.trace: StreamTrace = self.sched.trace
        if self.sched.admission is not None:
            # Keep admission counters live on the fresh trace, and route
            # deferred re-admission through the pipeline's own windowing/
            # backpressure accounting instead of the scheduler's
            # self-merge.
            self.trace.admission = self.sched.admission.counters
            self.sched.admission.external_defer = True
        self._ema: float | None = None   # "measured" latency model state
        self._defer_time = -np.inf       # last instant admission deferred

    # -- solver latency model ------------------------------------------------
    def _model_latency(self) -> float:
        if self.config.solver_latency == "measured":
            # EMA of observed solve walls; until the first observation the
            # model falls back to the warmup seed (:meth:`seed_latency` —
            # the scheduler's compile-excluded post-warmup solve wall), or
            # 0.0 on unwarmed runs.
            return self._ema if self._ema is not None else 0.0
        return float(self.config.solver_latency)

    def seed_latency(self, wall_s: float) -> None:
        """Seed the ``"measured"`` EMA before any traffic (cold-start fix):
        without this the first window's solve is modeled at 0 s, so its
        commit — and every latency in it — ignores real solver delay.
        ``run_stream(warmup=True)`` passes the warmup's compile-excluded
        solve wall here.  A no-op once an observation exists."""
        if self._ema is None and float(wall_s) > 0.0:
            self._ema = float(wall_s)

    def _observe_solve(self, wall_s: float) -> None:
        if self._ema is None:
            self._ema = wall_s
        else:
            self._ema = 0.5 * self._ema + 0.5 * wall_s

    # -- the event loop ------------------------------------------------------
    def run(self, stream: Iterable[tuple[float, Sequence[J.InferenceJob]]],
            *, horizon: float | None = None,
            pad_to: int | None = None,
            fault_schedule=None, recovery: str = "requeue",
            max_retries: int = 3) -> StreamTrace:
        """Drive ``(t, jobs)`` epochs (nondecreasing ``t``) to completion.

        ``horizon`` clamps the last partial window's flush (a window opened
        near the end of the stream flushes at ``min(open + window_s,
        horizon)`` rather than waiting out the full δ).  Every admitted
        request is committed before returning; shed requests are recorded
        in ``trace.shed`` with a ``reason``.

        ``fault_schedule`` (any iterable of
        :class:`~repro.serving.faults.FaultEvent`) pushes infrastructure
        events into the same event heap; they apply *before* any commit at
        the same instant and strand/recover work per ``recovery`` (see
        :class:`~repro.serving.faults.FaultInjector`) — requires
        ``drain="exact"``.
        """
        self._pad_to = pad_to
        self._horizon = horizon
        self._events: list[tuple] = []          # (time, kind, seq, payload)
        self._seq = itertools.count()
        self._stream = iter(stream)
        self._window: list[_Admit] = []
        self._window_open = 0.0
        self._wid = 0                           # current open window's id
        self._windows_made = 0
        self._solver_q: collections.deque[_Window] = collections.deque()
        self._busy = False
        self._spill: collections.deque[tuple[float, J.InferenceJob]] = (
            collections.deque())
        self._pending = 0
        self._last_t = -np.inf
        self._injector = None
        if fault_schedule is not None:
            from .faults import FaultInjector
            self._injector = FaultInjector(self.sched, policy=recovery,
                                           max_retries=max_retries,
                                           pad_to=pad_to)
            for ev in fault_schedule:
                if horizon is None or ev.time <= horizon:
                    self._push(ev.time, _FAULT, ev)

        self._pull_arrival()
        while self._events:
            self._step()
        # Drain-out: requests the admission layer still holds deferred when
        # the stream ends get one final assessment in ``final`` mode —
        # admitted ones commit, predicted misses are shed (deadline_miss,
        # charged from their original arrival), never re-deferred, so the
        # sweep terminates.
        ctl = self.sched.admission
        while ctl is not None and ctl.deferred:
            ctl.final = True
            try:
                t = self.sched.now
                for job, a0 in ctl.pop_deferred():
                    self._admit(job, arrival_s=a0, admit_s=t)
                if self._window:
                    self._close_window(t)
                while self._events:
                    self._step()
            finally:
                ctl.final = False
        assert self._pending == 0 and not self._spill and not self._window
        return self.trace

    def _step(self) -> None:
        t, kind, _, payload = heapq.heappop(self._events)
        if kind == _ARRIVAL:
            for job in payload:
                self._ingest(t, job)
            self._pull_arrival()
        elif kind == _FLUSH:
            if payload == self._wid and self._window:
                self._close_window(t)
        elif kind == _FAULT:
            self._injector.apply(payload)
            # Fault events are exactly when the committed plan can go
            # stale: give the auto-replan monitor (if armed) a look.
            self.sched.check_replan()
        else:  # _COMMIT
            self._commit(t, *payload)

    def _push(self, t: float, kind: int, payload) -> None:
        heapq.heappush(self._events, (t, kind, next(self._seq), payload))

    def _pull_arrival(self) -> None:
        epoch = next(self._stream, None)
        if epoch is None:
            return
        t, jobs = float(epoch[0]), list(epoch[1])
        if t < self._last_t:
            raise ValueError(
                f"arrival stream went backwards: {t} < {self._last_t}")
        self._last_t = t
        self._push(t, _ARRIVAL, jobs)

    # -- ingestion + backpressure -------------------------------------------
    def _ingest(self, t: float, job: J.InferenceJob) -> None:
        cfg = self.config
        if cfg.max_pending is not None and self._pending >= cfg.max_pending:
            if cfg.policy == "shed":
                self.trace.shed.append({"time": t, "name": job.name,
                                        "reason": "backpressure"})
            else:
                self._spill.append((t, job))
                self.trace.deferred += 1
            return
        self._admit(job, arrival_s=t, admit_s=t)

    def _admit(self, job: J.InferenceJob, *, arrival_s: float,
               admit_s: float) -> None:
        cfg = self.config
        if not self._window:
            self._window_open = admit_s
            self._wid += 1
            flush_at = admit_s + cfg.window_s
            if self._horizon is not None:
                flush_at = max(admit_s, min(flush_at, self._horizon))
            self._push(flush_at, _FLUSH, self._wid)
        self._window.append(_Admit(job, arrival_s, admit_s))
        self._pending += 1
        if len(self._window) >= cfg.max_batch:
            self._close_window(admit_s)

    # -- batching window -> solver stage ------------------------------------
    def _close_window(self, t: float) -> None:
        w = _Window(self._windows_made, self._window_open,
                    list(self._window), close_s=t)
        self._windows_made += 1
        self._window.clear()
        self._wid += 1                      # invalidate the pending flush
        self._solver_q.append(w)
        self._maybe_start(t)

    def _maybe_start(self, t: float) -> None:
        if self._busy or not self._solver_q:
            return
        # Batched mode drains up to fuse_windows queued windows per solver
        # start — one cross-arrival fused dispatch clears all of them, so
        # a backed-up solver catches up k windows per modeled latency d
        # instead of one.  Sequential mode keeps one window per start
        # (width-1 solves have no multi-window device program).
        k = (self.config.fuse_windows
             if self.config.solve_mode == "batched" else 1)
        ctl = self.sched.admission
        if ctl is not None and ctl.gating:
            # Admission gates windows one at a time (submit_windows would
            # commit candidates before they can be assessed).
            k = 1
        ws = [self._solver_q.popleft()]
        while len(ws) < k and self._solver_q:
            ws.append(self._solver_q.popleft())
        d = self._model_latency()
        self._busy = True
        self._push(t + d, _COMMIT, (ws, d))

    # -- solver commit stage -------------------------------------------------
    def _commit(self, t: float, ws: list[_Window], d: float) -> None:
        if self._injector is not None and self.sched.degraded:
            # Commit-time routability: the topology may have degraded since
            # these requests were admitted; a request whose endpoints are
            # dead or partitioned now has no serveable plan.
            for w in ws:
                live = [a for a in w.jobs
                        if self._injector.routable(int(a.job.src),
                                                   int(a.job.dst))]
                for a in w.jobs:
                    if a not in live:
                        self.trace.shed.append(
                            {"time": t, "name": a.job.name,
                             "reason": "unroutable"})
                        self._pending -= 1
                w.jobs = live
        nonempty = [w for w in ws if w.jobs]
        walls: dict[int, float] = {}
        ctl = self.sched.admission
        pre_defer = len(ctl.deferred) if ctl is not None else 0
        if nonempty:
            jobs_w = [[a.job for a in w.jobs] for w in nonempty]
            arrs_w = [[a.arrival_s for a in w.jobs] for w in nonempty]
            if len(nonempty) == 1:
                one = self._solve_window(t, jobs_w[0], arrs_w[0])
                per = None if one is None else [one]
            else:
                per = self._solve_windows(t, jobs_w, arrs_w)
            wall = self.sched.last_solve_s
            if per is None:           # solver died twice: shed the group
                for w in nonempty:
                    for a in w.jobs:
                        self.trace.shed.append(
                            {"time": t, "name": a.job.name,
                             "reason": "solver_error"})
                        self._pending -= 1
                    w.jobs = []
                    walls[id(w)] = wall / len(nonempty)
            else:
                # A wall that paid a jit compile would poison the EMA (the
                # model would predict compile-sized latency for every
                # following solve); record it separately instead.
                if bool(self.sched.stats().get("jit_compiled", False)):
                    self.trace.compile_walls.append(wall)
                else:
                    self._observe_solve(wall)
                for w, placements in zip(nonempty, per):
                    walls[id(w)] = (
                        float(placements[0].plan.meta.get(
                            "solve_share_s", wall / len(nonempty)))
                        if placements else wall / len(nonempty))
                    bound = {p.job_name: p.bound_s for p in placements}
                    for a in w.jobs:
                        # A window job missing from the placements was shed
                        # or deferred by the admission assessment inside
                        # submit_window — the scheduler already recorded it.
                        if a.job.name in bound:
                            self.trace.requests.append(RequestRecord(
                                name=a.job.name, window=w.index,
                                arrival_s=a.arrival_s, admit_s=a.admit_s,
                                close_s=w.close_s, commit_s=t,
                                solve_s=d, service_s=bound[a.job.name]))
                    self._pending -= len(w.jobs)
        if ctl is not None and len(ctl.deferred) > pre_defer:
            # Deferred at this instant: re-admitting before time advances
            # would re-run the identical assessment and loop — _release
            # holds them until a strictly later commit (or the end-of-run
            # drain-out sweep).
            self._defer_time = t
        for w in ws:
            self._finish_window(t, w, d, wall=walls.get(id(w), 0.0))
        self._release(t)

    def _solve_window(self, t: float, jobs, arrivals):
        """One window's solve with the robustness contract: a solver
        exception must not kill the pipeline.  A clean failure (nothing
        committed) is retried once; a *partial* failure (sequential mode
        committed a prefix before the raise) is rolled back through the
        ledger's withdrawal machinery — the raise happened at the commit
        instant, so zero served work is discarded — and not retried
        (committed names are unique for the ledger's lifetime, so the same
        requests cannot be resubmitted).  Returns ``None`` when the window
        commits nothing; the caller sheds it with ``reason:
        "solver_error"``."""
        sched = self.sched
        for attempt in (0, 1):
            pre = (sched.ledger.names_seen if sched.ledger is not None
                   else frozenset())
            try:
                return sched.submit_window(
                    t, jobs, arrivals=arrivals, pad_to=self._pad_to,
                    solve_mode=self.config.solve_mode)
            except Exception:  # noqa: BLE001 — serving must survive
                landed = (sorted(sched.ledger.names_seen - pre)
                          if sched.ledger is not None else [])
                if landed:
                    sched.ledger = sched.ledger.remove_jobs(landed, at=t)
                    if sched.commit_log is not None:
                        sched.commit_log = sched.commit_log.record_removal(
                            t, landed)
                    sched._sync_ledger_queues()
                    sched._last = None
                    return None
        return None

    def _solve_windows(self, t: float, jobs_w, arrs_w):
        """Cross-arrival fused solve of several windows, with the same
        robustness contract as :meth:`_solve_window`: a clean failure is
        retried once, a partial failure (some windows committed before the
        raise) is rolled back through the ledger and not retried.  Returns
        per-window placement lists, or ``None`` when nothing commits."""
        sched = self.sched
        for attempt in (0, 1):
            pre = (sched.ledger.names_seen if sched.ledger is not None
                   else frozenset())
            try:
                return sched.submit_windows(t, jobs_w, arrivals=arrs_w,
                                            pad_to=self._pad_to)
            except Exception:  # noqa: BLE001 — serving must survive
                landed = (sorted(sched.ledger.names_seen - pre)
                          if sched.ledger is not None else [])
                if landed:
                    sched.ledger = sched.ledger.remove_jobs(landed, at=t)
                    if sched.commit_log is not None:
                        sched.commit_log = sched.commit_log.record_removal(
                            t, landed)
                    sched._sync_ledger_queues()
                    sched._last = None
                    return None
        return None

    def _finish_window(self, t: float, w: _Window, d: float,
                       *, wall: float) -> None:
        self.trace.windows.append(WindowRecord(
            index=w.index, open_s=w.open_s, close_s=w.close_s, commit_s=t,
            size=len(w.jobs), solve_model_s=d, solve_wall_s=wall))

    def _release(self, t: float) -> None:
        """Free the solver server after a commit group lands."""
        self._busy = False
        # Commits free buffer capacity: re-admit deferred arrivals FIFO —
        # before any later traffic — so backpressure never reorders them.
        cfg = self.config
        while self._spill and (cfg.max_pending is None
                               or self._pending < cfg.max_pending):
            arr_t, job = self._spill.popleft()
            self._admit(job, arrival_s=arr_t, admit_s=t)
        # Admission-deferred requests re-enter through the same ingestion
        # path (original arrival preserved — a later expiry is charged from
        # it), but only once the clock has moved past the commit that
        # deferred them: the very same assessment would just bounce them
        # again.
        ctl = self.sched.admission
        if ctl is not None and ctl.deferred and t > self._defer_time:
            for job, a0 in ctl.pop_deferred():
                if (cfg.max_pending is not None
                        and self._pending >= cfg.max_pending):
                    self._spill.append((a0, job))
                    self.trace.deferred += 1
                else:
                    self._admit(job, arrival_s=a0, admit_s=t)
        self._maybe_start(t)


def run_stream(scenario, *, horizon: float, seed: int = 0,
               process: str = "poisson", rate: float | None = None,
               batch_size: int = 1, window_s: float = 0.0,
               max_batch: int = 1, solve_mode: str = "batched",
               solver_latency: float | str = 0.0,
               max_pending: int | None = None, policy: str = "defer",
               fuse_windows: int = 1, warmup: bool = False,
               method: str = "greedy", drain_queues: bool = True,
               finish: bool = False, pad_to: int | None = None,
               process_params: dict | None = None,
               fault_schedule=None, recovery: str = "requeue",
               max_retries: int = 3,
               deadline_s: float | None = None,
               admission=None, auto_replan=None,
               **solver_opts) -> StreamTrace:
    """Drive a scenario through the streaming pipeline; return the trace.

    The streaming counterpart of :func:`repro.serving.online.run_online`,
    sharing its scenario protocol, arrival processes and the ``rate``
    shorthand (:func:`repro.core.arrivals.resolve_rate`) — identical
    arguments produce the *identical* arrival stream and job sequence, so
    with ``window_s=0, max_batch=1, solver_latency=0`` the returned trace
    is bit-identical to the serial loop's.  ``window_s``/``max_batch``/
    ``solver_latency``/``max_pending``/``policy`` populate the
    :class:`StreamConfig`; everything else reaches the underlying
    :class:`OnlineScheduler` unchanged (``drain="fluid" | "exact"``,
    ``track_commits=``, ...).  ``finish=True`` runs the same end-of-run
    accounting as the serial loop (exact ledger served to completion,
    commit log replayed).  ``fault_schedule``/``recovery``/``max_retries``
    inject infrastructure events into the pipeline's event heap (see
    :meth:`StreamingPipeline.run`) — requires ``drain="exact"``.

    ``fuse_windows`` reaches the :class:`StreamConfig` (cross-arrival
    fused dispatch of queued windows); ``warmup=True`` pre-compiles the
    fused solve at this run's serving shapes
    (:meth:`~repro.serving.scheduler.RoutedScheduler.warmup`) before any
    traffic, so the ``"measured"`` latency model never sees a compile
    wall — and its compile-excluded post-warmup solve wall *seeds* the
    ``"measured"`` EMA, so even the very first window's commit models
    real solver delay instead of the cold-start 0.  Warmup samples
    throwaway jobs from the scenario, which advances its shared job-name
    counter — a warmed run's job *names* differ from an unwarmed one's
    (values are unaffected).

    ``deadline_s`` attaches a uniform relative SLO to every streamed job
    (a job's own finite ``deadline_s`` wins); ``admission`` /
    ``auto_replan`` reach the underlying :class:`OnlineScheduler` exactly
    as in :func:`~repro.serving.online.run_online` — deferred arrivals
    re-enter through the pipeline's own ingestion path (original arrival
    preserved) and get a final drain-out assessment when the stream ends.
    """
    rng = np.random.default_rng(seed)
    params = A.resolve_rate(process, rate, process_params)
    times = A.stream_times(process, rng, horizon, **params)
    cfg = StreamConfig(window_s=window_s, max_batch=max_batch,
                       solve_mode=solve_mode,
                       solver_latency=solver_latency,
                       max_pending=max_pending, policy=policy,
                       fuse_windows=fuse_windows)
    sched = OnlineScheduler(scenario.topology, method=method,
                            drain_queues=drain_queues, admission=admission,
                            auto_replan=auto_replan, **solver_opts)
    pipe = StreamingPipeline(sched, cfg)
    if pad_to is None:
        pad_to = getattr(scenario, "max_layers", None)
    if warmup:
        wrng = np.random.default_rng(seed)
        counts = (fuse_windows,) if fuse_windows > 1 else ()
        winfo = sched.warmup(scenario.sample_jobs(wrng, max(max_batch, 1)),
                             pad_to=pad_to, window_counts=counts)
        pipe.seed_latency(float(winfo.get("warm_solve_s", 0.0)))
    if hasattr(scenario, "job_stream"):
        stream = scenario.job_stream(rng, times, batch_size)
    else:
        stream = ((float(t), scenario.sample_jobs(rng, batch_size))
                  for t in times)
    if deadline_s is not None:
        def _with_slo(src, d=float(deadline_s)):
            for t, jobs in src:
                yield t, [j if np.isfinite(j.deadline_s)
                          else j.with_deadline(d) for j in jobs]
        stream = _with_slo(stream)
    pipe.run(stream, horizon=horizon, pad_to=pad_to,
             fault_schedule=fault_schedule, recovery=recovery,
             max_retries=max_retries)
    if finish:
        if sched.ledger is not None:
            sched.finish()
        if sched.commit_log is not None:
            sched.replay_ground_truth()
    pipe.trace.commit_log = sched.commit_log
    return pipe.trace
