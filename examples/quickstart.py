"""Quickstart: route DNN inference jobs over a computing network.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's 5-node topology, routes 2 VGG19 + 6 ResNet34 inference
jobs through the unified solver API (``solve(net, batch, method=...)`` ->
``Plan``), verifies the fictitious-system bound against the event-driven
simulator, and refines with SA (Alg. 2) — same call, different method
string.
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import registry
from repro.core import jobs as J, network as N, solve


def main():
    net, names = N.small_topology(capacity_scale=1e-3)
    rng = np.random.default_rng(0)
    jobs = []
    for i, kind in enumerate(["vgg19"] * 2 + ["resnet34"] * 6):
        src, dst = rng.choice(5, size=2, replace=False)
        jobs.append(registry.get(kind).make_job(f"{kind}-{i}",
                                                int(src), int(dst)))
    batch = J.batch_jobs(jobs)

    print("== greedy (Algorithm 1) ==")
    plan = solve(net, batch, method="greedy")
    for p, j in enumerate(plan.order):
        L = jobs[j].num_layers
        route = [names[jobs[j].src]] + [names[n] for n in
                                        dict.fromkeys(plan.assign[j][:L])] \
            + [names[jobs[j].dst]]
        print(f"  prio {p}: {jobs[j].name:12s} bound {plan.bounds[j]:8.3f}s "
              f"via {'->'.join(route)}")
    sim = plan.simulate(net, batch)
    print(f"  makespan: bound {plan.bound():.3f}s  "
          f"simulated {sim.makespan:.3f}s")
    assert sim.makespan <= plan.bound() + 1e-6

    print("== simulated annealing (Algorithm 2, warm-started) ==")
    sa = solve(net, batch, method="sa", seed=0, d=0.99, num_chains=4,
               init="greedy", block_move_prob=0.3)
    sim2 = sa.simulate(net, batch)
    print(f"  makespan: bound {sa.bound():.3f}s  simulated {sim2.makespan:.3f}s")

    # every plan is one JSON-serializable artifact, whatever solved it
    roundtrip = type(sa).from_dict(sa.to_dict())
    assert np.array_equal(roundtrip.assign, sa.assign)
    print(f"  plan serialized: solver={roundtrip.solver} "
          f"({len(str(sa.to_dict()))} chars)")
    print("OK")


if __name__ == "__main__":
    main()
