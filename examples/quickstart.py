"""Quickstart: route DNN inference jobs over a computing network.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's 5-node topology, routes 2 VGG19 + 6 ResNet34 inference
jobs with the greedy algorithm (Alg. 1), verifies the fictitious-system
bound against the event-driven simulator, and refines with SA (Alg. 2).
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import registry
from repro.core import annealing, greedy, jobs as J, network as N, schedule


def main():
    net, names = N.small_topology(capacity_scale=1e-3)
    rng = np.random.default_rng(0)
    jobs = []
    for i, kind in enumerate(["vgg19"] * 2 + ["resnet34"] * 6):
        src, dst = rng.choice(5, size=2, replace=False)
        jobs.append(registry.get(kind).make_job(f"{kind}-{i}",
                                                int(src), int(dst)))
    batch = J.batch_jobs(jobs)

    print("== greedy (Algorithm 1) ==")
    sol = greedy.greedy_route(net, batch)
    for p, j in enumerate(sol.order):
        L = jobs[j].num_layers
        route = [names[jobs[j].src]] + [names[n] for n in
                                        dict.fromkeys(sol.assign[j][:L])] \
            + [names[jobs[j].dst]]
        print(f"  prio {p}: {jobs[j].name:12s} bound {sol.bounds[j]:8.3f}s "
              f"via {'->'.join(route)}")
    sim = schedule.simulate(net, batch, sol.assign, sol.order)
    print(f"  makespan: bound {sol.makespan_bound:.3f}s  "
          f"simulated {sim.makespan:.3f}s")
    assert sim.makespan <= sol.makespan_bound + 1e-6

    print("== simulated annealing (Algorithm 2, warm-started) ==")
    sa = annealing.anneal(net, batch, seed=0, d=0.99, num_chains=4,
                          init="greedy", block_move_prob=0.3)
    sim2 = schedule.simulate(net, batch, sa.assign, sa.priority)
    print(f"  makespan: bound {sa.bound:.3f}s  simulated {sim2.makespan:.3f}s")
    print("OK")


if __name__ == "__main__":
    main()
