"""Streaming serving: batching window + decoupled solver vs the serial loop.

    PYTHONPATH=src python examples/streaming_serving.py

1. The same bursty arrival stream (bursts of ~4 requests) is driven through
   the serving stack twice: once by the serial per-arrival discipline
   (``window_s=0, max_batch=1`` — one solve per request, the
   ``run_online`` loop), once through a batching window (collect up to
   B=4 jobs or δ sim-seconds, then one padded batched solve).  Identical
   arrivals, identical jobs, identical drain.
2. The pipeline runs on a simulated clock with the solver as a stage on
   it (``solver_latency="measured"`` charges observed solve walls), so
   every request's latency decomposes into **wait** (window residence +
   solver queue + modeled solve) + **service** (the committed plan's
   bound) — time spent waiting for a batch is accounted, not hidden.
3. Backpressure: with a bounded pending buffer (``max_pending``) an
   overload burst is either **deferred** (held FIFO, re-admitted as
   commits free the buffer, the extra wait charged to latency) or
   **shed** (dropped and accounted) — the buffer bound holds either way.

``benchmarks/stream_bench.py`` measures the wall-clock throughput side:
one scheduler entry per window amortizes the per-arrival dispatch overhead
(drain sync, queue materialization, trace bookkeeping), sustaining higher
arrivals/sec at equal p99.  The solve inside a window is selectable —
``solve_mode="batched"`` (one padded solve) or ``"sequential"`` (width-1
solves in window order, committing exactly the serial loop's plans; wins
when the solver is compute-bound).
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.scenarios import make_scenario
from repro.serving.stream import StreamConfig, StreamingPipeline, run_stream


def main():
    sc0 = make_scenario("star", seed=0)
    rate = sc0.nominal_rate(0.6)
    print(f"scenario {sc0.name}: {sc0.num_nodes} nodes, "
          f"bursty arrivals at {rate:.3g}/s (60% offered load)\n")

    # -- serial vs windowed on the identical stream -------------------------
    runs = {}
    for label, cfg in [("serial (δ=0, B=1)", dict(window_s=0.0, max_batch=1)),
                       ("windowed (δ=0.05/λ, B=4)",
                        dict(window_s=0.05 / rate, max_batch=4))]:
        # fresh scenario per run => identical rng stream => identical jobs
        runs[label] = run_stream(make_scenario("star", seed=0),
                                 horizon=40 / rate, seed=9,
                                 process="bursty", rate=rate,
                                 solver_latency="measured", **cfg)

    print(f"{'':26s} {'requests':>8s} {'windows':>8s} {'solves':>7s} "
          f"{'p50 wait':>9s} {'p99 lat':>9s}")
    for label, tr in runs.items():
        s = tr.summary()
        print(f"{label:26s} {s['requests']:8d} {s['windows']:8d} "
              f"{s['windows']:7d} {s['p50_wait_s']:8.3f}s "
              f"{s['p99_latency_s']:8.3f}s")
    serial, windowed = runs.values()
    print(f"\nthe window turns {len(serial.windows)} solver calls into "
          f"{len(windowed.windows)} batched ones; the p99 cost of waiting "
          f"for the batch is "
          f"{windowed.summary()['p99_latency_s'] / serial.summary()['p99_latency_s'] - 1:+.1%} "
          f"(bursts arrive ~together, so a tiny δ captures whole bursts)")

    # per-request decomposition: latency == wait + service, request by request
    r = max(windowed.requests, key=lambda r: r.wait_s)
    print(f"slowest-waiting request {r.name!r}: arrived {r.arrival_s:.3f}s, "
          f"window closed {r.close_s:.3f}s, committed {r.commit_s:.3f}s\n"
          f"  latency {r.latency_s:.3f}s = wait {r.wait_s:.3f}s "
          f"(window {r.close_s - r.arrival_s:.3f}s + solver queue "
          f"{r.queue_s:.3f}s + solve {r.solve_s:.3f}s) "
          f"+ service {r.service_s:.3f}s")

    # -- backpressure: defer vs shed on an overload burst -------------------
    print("\n20-request burst into a pending buffer of 4, slow solver "
          "(0.3s/solve):")
    jobs = sc0.sample_jobs(np.random.default_rng(1), 20)
    for policy in ("defer", "shed"):
        pipe = StreamingPipeline(
            sc0.topology,
            StreamConfig(window_s=0.0, max_batch=4, solver_latency=0.3,
                         max_pending=4, policy=policy))
        tr = pipe.run(iter([(0.01 * i, [j]) for i, j in enumerate(jobs)]),
                      horizon=30.0, pad_to=sc0.max_layers)
        s = tr.summary()
        print(f"  policy={policy:5s}: committed {s['requests']:2d}  "
              f"deferred {s['deferred']:2d}  shed {s['shed']:2d}  "
              f"p99 wait {s['p99_wait_s']:.2f}s")
        if policy == "defer":
            # FIFO preserved: deferral never reorders same-priority arrivals
            assert [r.name for r in tr.requests] == [j.name for j in jobs]
            assert s["requests"] == 20 and s["shed"] == 0
        else:
            assert s["requests"] + s["shed"] == 20 and s["deferred"] == 0
    print("defer keeps every request (wait charged to latency); "
          "shed trades completeness for bounded wait")
    print("OK")


if __name__ == "__main__":
    main()
