"""Large-topology routing: the paper's 24-node US backbone experiment, plus
LM architectures from the assigned pool as inference jobs (layer-wise cost
profiles feed the same routing framework).

    PYTHONPATH=src python examples/us_backbone_routing.py
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import registry
from repro.core import jobs as J, network as N, solve


def main():
    net, names = N.us_backbone(capacity_scale=1e-2)
    rng = np.random.default_rng(7)
    jobs = []
    # the paper's mix ...
    for i, kind in enumerate(["vgg19"] * 6 + ["resnet34"] * 2):
        s, d = rng.choice(24, 2, replace=False)
        jobs.append(registry.get(kind).make_job(f"{kind}-{i}", int(s), int(d)))
    # ... plus two LM jobs from the assigned architecture pool
    for arch in ["smollm_135m", "xlstm_125m"]:
        s, d = rng.choice(24, 2, replace=False)
        comp, data = registry.get(arch).cost_profile(seq_len=1024, batch=1)
        jobs.append(J.InferenceJob(arch, int(s), int(d),
                                   comp.astype(np.float32),
                                   data.astype(np.float32)))
    batch = J.batch_jobs(jobs)
    plan = solve(net, batch, method="lazy")   # lazy greedy: same solution,
    sim = plan.simulate(net, batch)           # O(1) expected re-routes/round
    print(f"{'job':16s} {'bound(s)':>10s}  route")
    for p, j in enumerate(plan.order):
        L = jobs[j].num_layers
        hops = list(dict.fromkeys(plan.assign[j][:L]))
        print(f"{jobs[j].name:16s} {plan.bounds[j]:10.3f}  "
              f"{jobs[j].src}->{'/'.join(map(str, hops))}->{jobs[j].dst}")
    print(f"\nmakespan: bound {plan.bound():.3f}s "
          f"simulated {sim.makespan:.3f}s "
          f"({plan.meta['n_routings']} routings)")
    assert sim.makespan <= plan.bound() + 1e-6
    print("OK")


if __name__ == "__main__":
    main()
