"""End-to-end training driver: train a reduced smollm for a few hundred
steps on the deterministic synthetic pipeline, with checkpoints.

    PYTHONPATH=src python examples/train_smollm.py [--steps 200]

(The assignment's full configs are exercised by the 512-device dry-run; on
this CPU container the example trains the reduced config and demonstrates
loss descent + checkpoint/restart.)
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        res = train("smollm_135m", preset="smoke", steps=args.steps,
                    batch=args.batch, seq=args.seq, ckpt_dir=ckpt_dir,
                    ckpt_every=100, log_every=20, lr=3e-3)
    first = sum(res.losses[:10]) / 10
    last = sum(res.losses[-10:]) / 10
    print(f"loss: first10 {first:.4f} -> last10 {last:.4f}")
    assert last < first, "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()
