"""End-to-end driver: serve a small model with batched requests, placed by
the paper's routing framework.

    PYTHONPATH=src python examples/serve_routed.py

1. Model a 4-slice serving cluster as the paper's computing network.
2. A batch of inference requests arrives; the RoutedScheduler turns each
   into an InferenceJob (per-layer cost profile) and places it with
   Algorithm 1 — queue-aware, so load spreads and stragglers are avoided.
3. The DecodeEngine actually serves a batch of requests end-to-end
   (prefill + 24 decoded tokens) with a reduced smollm model on CPU.
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import registry
from repro.core import network as N
from repro.models import model as M
from repro.serving.engine import DecodeEngine
from repro.serving.scheduler import Request, RoutedScheduler


def main():
    # -- 1. cluster model: 4 slices, 2 edge ingress nodes
    G, GB = 1e12, 1e9
    net = N.make_network(
        6,
        [(0, 1, 10 * GB), (1, 2, 40 * GB), (2, 3, 40 * GB), (3, 4, 40 * GB),
         (4, 5, 10 * GB), (1, 3, 40 * GB), (2, 4, 40 * GB)],
        [0, 50 * G, 50 * G, 50 * G, 50 * G, 0])
    sched = RoutedScheduler(net)

    # -- 2. place a mixed batch of requests with the routing framework
    reqs = [Request("olmo_1b", src=0, dst=5, seq_len=2048, name=f"olmo-{i}")
            for i in range(4)]
    reqs += [Request("deepseek_v2_236b", src=0, dst=5, seq_len=2048,
                     name="dsv2-0")]
    plans = sched.schedule(reqs)
    print("placements (greedy, queue-aware):")
    for p in plans:
        print(f"  prio {p.priority}: {p.job_name:10s} slices {p.nodes_used} "
              f"bound {p.bound_s * 1e3:8.2f} ms")
    used = {n for p in plans for n in p.nodes_used}
    print(f"  -> load spread over {len(used)} slices")

    # a straggling slice: re-plan the *same* batch against the new health
    victim = plans[0].nodes_used[0]
    sched.report_slowdown(victim, 10.0)
    plans2 = sched.replan_last()
    moved = {n for p in plans2 for n in p.nodes_used}
    print(f"  straggler: slice {victim} reported 10x slow -> batch re-planned "
          f"onto {sorted(moved)}")

    # the whole placement is one Plan: serializable, solver-tagged
    plan = sched.last_plan
    print(f"  plan: solver={plan.solver} bound {plan.bound()*1e3:.2f} ms "
          f"({len(str(plan.to_dict()))} chars as JSON)")

    # -- 3. actually serve a batch of requests (reduced model, CPU)
    cfg = registry.smoke_config("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = DecodeEngine(cfg, params, max_len=64)
    prompts = np.tile(np.arange(8, dtype=np.int32)[None], (4, 1))
    res = engine.generate(prompts, gen_len=24)
    print(f"served batch of 4: prefill {res.prefill_s:.2f}s, "
          f"decode {res.decode_s:.2f}s ({res.tokens_per_s:.1f} tok/s)")
    print(f"sample tokens: {res.tokens[0][:10].tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
