"""Failure recovery: an edge-cloud day with a mid-run outage.

    PYTHONPATH=src python examples/failure_recovery.py

The same Poisson arrival stream (85% offered load) is driven through the
edge-cloud scenario four times.  A ``transient-node`` fault schedule
fails the cloud node mid-horizon and recovers it later; each run differs
only in what happens to the work stranded on it:

  requeue   residuals re-planned onto the surviving topology with the
            regular solver (re-transfer paid from the node holding the
            last finished layer's output);
  migrate   residuals moved wholesale to one chosen node (the
            ``"migrate"`` solver's argmin placement);
  lost      stranded work shed and accounted.

The baseline is a **clairvoyant oracle** that solved against the
post-failure topology from t=0: it never places work on the victim, so
it pays zero disruption — but also forgoes the victim's capacity for the
whole horizon.  The gap to it is the price of not knowing the future.

Ground truth stays exact throughout: every run's completion times are
re-derived by replaying the commit log segment by segment through the
recorded health/removal history (``replay_piecewise``) and compared to
the incremental drain.
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.scenarios import make_scenario
from repro.serving import faults as F
from repro.serving.online import run_online


def main():
    sc = make_scenario("edge-cloud", seed=0)
    load, arrivals = 0.85, 32
    rate = sc.nominal_rate(load)
    horizon = arrivals / rate

    faults = F.make_fault_schedule("transient-node", sc, horizon, seed=7)
    victim = faults.events[0].node
    t_fail, t_back = (ev.time for ev in faults)
    print(f"scenario {sc.name}: {sc.num_nodes} nodes, ~{arrivals} arrivals "
          f"at {rate:.3g}/s ({load:.0%} load) over {horizon:.0f}s")
    print(f"fault: node {victim} (the cloud) down "
          f"{t_fail:.0f}s-{t_back:.0f}s "
          f"({(t_back - t_fail) / horizon:.0%} of the horizon)\n")

    def drive(schedule, policy):
        # fresh scenario per run => identical rng stream => identical jobs
        return run_online(make_scenario("edge-cloud", seed=0),
                          horizon=horizon, rate=rate, seed=7, drain="exact",
                          track_commits=True, finish=True,
                          fault_schedule=schedule, recovery=policy)

    oracle = drive(F.FaultSchedule((F.node_fail(0.0, victim),)), "lost")
    runs = {policy: drive(faults, policy) for policy in F.POLICIES}

    def p99(tr):
        act = tr.actual_latencies()
        return float(np.percentile(act, 99)) if act.size else float("nan")

    print(f"{'policy':10s} {'done':>5s} {'requeued':>8s} {'lost':>5s} "
          f"{'p99 actual':>11s} {'vs oracle':>9s} {'replay':>7s}")
    o99 = p99(oracle)
    print(f"{'oracle':10s} {len(oracle.completions):5d} {'-':>8s} "
          f"{len(oracle.lost):5d} {o99:10.1f}s {'1.00x':>9s} {'':>7s}")
    for policy, tr in runs.items():
        requeued = sum(1 for n in tr.completions if "#r" in n)
        gap = max((abs(tr.completions[n] - tr.replay_completions[n])
                   for n in tr.completions), default=0.0)
        assert set(tr.completions) == set(tr.replay_completions)
        assert gap <= 1e-6, f"replay diverged under {policy}: {gap}"
        print(f"{policy:10s} {len(tr.completions):5d} {requeued:8d} "
              f"{len(tr.lost):5d} {p99(tr):10.1f}s "
              f"{p99(tr) / o99:8.2f}x {'exact':>7s}")
    for policy, tr in runs.items():
        if tr.lost:
            reasons = {}
            for _, why in tr.lost:
                reasons[why] = reasons.get(why, 0) + 1
            print(f"  {policy}: lost by reason {reasons}")

    # -- one requeued job's latency, decomposed around the outage -----------
    tr = runs["requeue"]
    requeued = [n for n in tr.completions if "#r" in n]
    if requeued:
        n = min(requeued, key=lambda n: tr.arrivals_by_name[n])
        arr = tr.arrivals_by_name[n]
        done = tr.completions[n]
        base, _ = F._parse_retry(n)
        print(f"\nrequeued request {base!r}: arrived {arr:.1f}s, stranded by "
              f"the {t_fail:.0f}s outage, re-planned as {n!r} on the "
              f"surviving topology")
        print(f"  latency {done - arr:.1f}s = {t_fail - arr:.1f}s before "
              f"the failure + {done - t_fail:.1f}s to re-plan, re-transfer "
              f"and finish (charged from the ORIGINAL arrival)")

    print(f"\nthe oracle forgoes node {victim} for the whole horizon; "
          f"reactive requeue uses it before and after the outage, paying "
          f"re-transfer only for work the failure actually stranded")
    print("OK")


if __name__ == "__main__":
    main()
