"""Online serving: an edge-cloud deployment through a diurnal traffic day.

    PYTHONPATH=src python examples/online_serving.py

1. ``scenarios.make_scenario("edge-cloud")`` builds the split-computing
   deployment: edge sites with thin compute, an aggregation tier, one fat
   cloud node; LM traffic cost-profiled from the config registry.
2. A diurnal arrival stream (nonhomogeneous Poisson: quiet at night,
   peaking mid-day) drives the OnlineScheduler.  Before each batch is
   solved the queue state is **drained** to the arrival time — committed
   work has been getting served in the meantime — so backlog tracks the
   daily load curve instead of ratcheting upward.
3. Mid-day the cloud node degrades 4x (straggler event on the same clock);
   the last batch is re-placed against the degraded health, and subsequent
   placements route cost-optimally around or through it until it recovers
   in the afternoon.
4. The same day is re-run with ``drain="exact"``: instead of the fluid
   model (every resource drains at full rate), a committed-work ledger
   drains exactly the committed jobs through the event simulator's
   preempt-resume loop — the backlog it reports is what the committed work
   actually costs, and every latency bound is checked against the true
   completion times.
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import arrivals as A
from repro.scenarios import make_scenario
from repro.serving.online import OnlineScheduler


def main():
    sc = make_scenario("edge-cloud", seed=0)
    cloud = sc.num_nodes - 1
    print(f"scenario {sc.name}: {sc.num_nodes} nodes "
          f"({', '.join(sc.node_names)}), traffic '{sc.traffic.name}', "
          f"mean service {sc.mean_service_s:.2f}s")

    # A compressed "day": diurnal rate between 10% and 45% offered load,
    # scaled so the day sees ~120 requests.
    base, peak = sc.nominal_rate(0.10), sc.nominal_rate(0.45)
    day = 120 / (base + (peak - base) / 2)
    rng = np.random.default_rng(7)
    times = A.diurnal_times(rng, base, peak, day, period=day)
    print(f"diurnal day of {day:,.0f}s, {times.size} arrivals "
          f"(rate {base:.3g}/s night -> {peak:.3g}/s midday)\n")

    sched = OnlineScheduler(sc.topology, method="greedy")
    slowdown_at, recover_at = 0.5 * day, 0.7 * day
    degraded = recovered = False
    cloud_hits_during_outage = 0
    for t in times:
        if not degraded and t >= slowdown_at:
            sched.report_slowdown(cloud, 4.0, at=slowdown_at)
            degraded = True
            replans = sched.replan_last() or []
            moved = sorted({n for p in replans for n in p.nodes_used})
            names = [sc.node_names[n] for n in moved]
            print(f"  [{slowdown_at:9.1f}s] cloud degraded 4x -> last batch "
                  f"re-placed onto {names} (cost-optimal under the "
                  f"degraded health, which may still be the cloud)")
        if degraded and not recovered and t >= recover_at:
            sched.report_slowdown(cloud, 1.0, at=recover_at)
            recovered = True
            print(f"  [{recover_at:9.1f}s] cloud recovered")
        placements = sched.submit_jobs(float(t), sc.sample_jobs(rng, 1),
                                       pad_to=sc.max_layers)
        if degraded and not recovered:
            cloud_hits_during_outage += sum(
                cloud in p.nodes_used for p in placements)

    tr = sched.trace
    print(f"\nday served: {len(tr.records)} arrivals, "
          f"placements touching degraded cloud during outage: "
          f"{cloud_hits_during_outage}")
    quarters = np.array_split(np.arange(len(tr.records)), 4)
    labels = ["night", "morning ramp", "midday peak*", "afternoon"]
    print("quarter          arrivals   p50 lat    p99 lat   max backlog")
    peak_backlog = 0.0
    for idx, label in zip(quarters, labels):
        lats = np.concatenate([np.asarray(tr.records[i].latencies)
                               for i in idx]) if idx.size else np.array([0.0])
        backs = [tr.records[i].backlog_after for i in idx] or [0.0]
        peak_backlog = max(peak_backlog, max(backs))
        print(f"{label:16s} {idx.size:8d}  {np.percentile(lats, 50):8.2f}s "
              f"{np.percentile(lats, 99):9.2f}s  {max(backs):10.2f}s")
    print("(* straggler event mid-quarter)")
    final = tr.records[-1].backlog_after
    print(f"peak backlog {peak_backlog:.2f}s -> end of day {final:.2f}s: the "
          f"outage bubble drains once the cloud recovers\n"
          f"(the legacy no-drain loop's backlog only ever climbs)")
    assert final < peak_backlog

    # -- the same day under exact (committed-work) drain accounting ---------
    print("\nre-running the quiet half of the day with drain='exact' "
          "(per-plan completion tracking)...")
    rng = np.random.default_rng(7)
    exact = OnlineScheduler(sc.topology, method="greedy", drain="exact")
    for t in times[times < slowdown_at]:
        exact.submit_jobs(float(t), sc.sample_jobs(rng, 1),
                          pad_to=sc.max_layers)
    completions = exact.finish()  # serve everything committed to completion
    etr = exact.trace
    bounds = etr.latencies
    actual = etr.actual_latencies()
    assert actual.size == bounds.size == len(completions)
    assert (actual <= bounds * (1 + 1e-6) + 1e-9).all()
    print(f"  {len(completions)} requests: p99 bound "
          f"{np.percentile(bounds, 99):.2f}s vs p99 actual completion "
          f"{np.percentile(actual, 99):.2f}s — every bound dominates its "
          f"actual (the fluid model cannot promise that; "
          f"see BENCH_online.json fidelity section)")
    print("OK")


if __name__ == "__main__":
    main()
